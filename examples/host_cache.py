"""Host-tier block cache example: the two-level cliff (DESIGN.md §14).

Three views of the stacked host-cache + SSD hierarchy on the diurnal
`flush_burst` scenario:

 1. write policy — write-back vs write-through vs no host tier: the wb
    tier absorbs most of the write stream (host hit rate > 0, device-
    visible writes well below trace writes) and host-visible write
    latency collapses to the DRAM-tier hit time.
 2. per-tier timelines — host windows (hits, dirty level, flush bursts)
    against device windows: watermark flush bursts land on the device as
    write-back volume, and where a burst overlaps SLC reclamation the
    device-visible window latency spikes (the flush-burst-vs-reclamation
    interaction window).
 3. the two-level cliff — on the bursty rewrite the host-visible write
    latency is FLAT (wb absorbs everything at hit_ms), while the
    device-visible latency series still cliffs when the SLC cache
    exhausts. `detect_cliff` on the device-visible series surfaces it:
    baseline cliffs early; IPS defers reclamation stalls (later onset,
    less total device time) — the paper's cliff story, now one tier down.

Run: PYTHONPATH=src python examples/host_cache.py [--max-ops N]
"""
import argparse

import numpy as np


def _series(hw):
    """Device-visible per-window mean latency + device ops from a
    HostWindows record — the series the cliff detector consumes."""
    dev_n = np.asarray(hw.dev_ops + hw.flush_w + hw.evict_w, np.float64)
    dev_lat = np.asarray(hw.dev_lat_ms, np.float64)
    mean = np.where(dev_n > 0, dev_lat / np.maximum(dev_n, 1), np.nan)
    return mean, dev_n


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--max-ops", type=int, default=None)
    ap.add_argument("--window-ops", type=int, default=1024)
    args = ap.parse_args()

    from repro.configs.ssd_paper import PAPER_SSD
    from repro.core.ssd.sim import CTR, run_trace, summarize
    from repro.hostcache import HostCacheSpec
    from repro.telemetry.timeline import detect_cliff
    from repro.workloads.generators import flush_burst

    cfg = PAPER_SSD.scaled(128)
    n_logical = min(cfg.total_pages, 1 << 16)
    base = flush_burst(n_logical, capacity_pages=cfg.total_pages)
    if args.max_ops:
        base = base.truncate(args.max_ops)
    daily = base.compile()
    bursty = base.to_bursty(n_logical).compile()
    isw = np.asarray(daily["is_write"])
    trace_w = int((isw == 1).sum())

    # -- 1. write policy: wb vs wt vs no host tier ----------------------
    print(f"flush_burst daily, ips policy ({trace_w} trace writes)")
    print(f"{'tier':<14}{'hit rate':>9}{'dev wr':>8}{'dev/trace':>10}"
          f"{'host lat ms':>12}")
    variants = [("off", None), ("wb:watermark", HostCacheSpec()),
                ("wt", HostCacheSpec(mode="wt"))]
    for label, hc in variants:
        lat, st = run_trace(cfg, "ips", daily, closed_loop=False,
                            n_logical=n_logical, hostcache=hc)
        s = summarize(lat, {"is_write": isw}, st)
        dev_w = float(np.asarray(st.counters)[CTR["host_w"]])
        hit = float(s.get("host_hit_rate", 0.0))
        print(f"{label:<14}{hit:>9.3f}{dev_w:>8.0f}"
              f"{dev_w / trace_w:>10.3f}"
              f"{float(s['mean_write_latency_ms']):>12.4f}")

    # -- 2. per-tier timelines on the diurnal trace ---------------------
    w = args.window_ops
    _, st = run_trace(cfg, "ips", daily, closed_loop=False,
                      n_logical=n_logical, hostcache=HostCacheSpec(),
                      timeline_ops=w)
    hw = st.hostcache.hwin
    mean, dev_n = _series(hw)
    live = np.asarray(hw.absorbed + hw.dev_ops) > 0
    print(f"\nper-tier windows ({w} ops each; host tier above, device "
          f"view below):")
    print(f"{'win':>4}{'hits':>7}{'dirty%':>8}{'flush_w':>8}"
          f"{'dev ops':>8}{'dev lat/op ms':>14}")
    idx = np.flatnonzero(live)
    for i in idx[:: max(1, len(idx) // 16)]:
        print(f"{i:>4}{float(hw.hits[i]):>7.0f}"
              f"{100 * float(hw.dirty_frac[i]):>7.1f}%"
              f"{float(hw.flush_w[i]):>8.0f}{dev_n[i]:>8.0f}"
              f"{mean[i] if dev_n[i] else 0.0:>14.3f}")
    burst = np.asarray(hw.flush_w) > 0
    if burst.any() and dev_n[~burst & live].sum() > 0:
        in_b = mean[burst & (dev_n > 0)]
        out_b = mean[~burst & live & (dev_n > 0)]
        print(f"flush-burst windows: {int(burst.sum())}; device lat/op "
              f"{np.nanmean(in_b):.3f} ms inside bursts vs "
              f"{np.nanmean(out_b):.3f} ms outside — the "
              f"flush-burst-vs-reclamation interaction window")

    # -- 3. the two-level cliff (bursty rewrite) ------------------------
    print("\nbursty rewrite, wb host tier — device-visible cliff:")
    for pol in ("baseline", "ips"):
        lat, st = run_trace(cfg, pol, bursty, closed_loop=True,
                            n_logical=n_logical, hostcache=HostCacheSpec(),
                            timeline_ops=w)
        s = summarize(lat, {"is_write": np.asarray(bursty["is_write"])},
                      st)
        mean, dev_n = _series(st.hostcache.hwin)
        cliff = detect_cliff(mean, dev_n, window_ops=w)
        host_lat = float(s["mean_write_latency_ms"])
        tot = float(st.hostcache.dev_lat_ms)
        where = (f"window {cliff['window']} "
                 f"({cliff['ratio']:.1f}x steady)" if cliff["detected"]
                 else "none")
        print(f"  {pol:<9} host-visible lat {host_lat:.4f} ms (flat), "
              f"device cliff: {where}, total device ms {tot:.0f}")


if __name__ == "__main__":
    main()
