"""Workload-engine zoo: every way to get a trace, through one interface.

Builds the 11 synthetic MSR traces, each parametric scenario generator, a
real trace file (the test fixture), and a multi-tenant mix; fits
`TraceStats` back from each and prints the zoo as a table — the round-trip
that validates the synthetic path against real inputs (DESIGN.md §7).

Run: PYTHONPATH=src python examples/workload_zoo.py [--simulate]

--simulate additionally runs a tiny fleet sweep over one workload of each
kind (MSR name, scenario, file) to show they share the simulator path.
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro import workloads as wl

N_LOGICAL = 1 << 16
CAPACITY = 786432                       # scale-128 drive, in pages
FIXTURE = os.path.join(os.path.dirname(__file__), "..", "tests", "data",
                       "sample_msr.csv")


def show(label: str, trace: wl.Trace) -> None:
    st = wl.fit_stats(trace, N_LOGICAL, CAPACITY)
    print(f"{label:<26} {trace.n_ops:>8} ops {trace.n_reqs:>7} reqs  "
          f"wr={st.write_ratio:.2f} seq={st.seq_prob:.2f} "
          f"ws={st.working_set_frac:.4f} skew={st.skew:.1f} "
          f"ia={st.interarrival_ms:.2f}ms "
          f"idle={st.idle_ms:.0f}ms/{st.idle_every}")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--simulate", action="store_true",
                    help="also run a 3-workload fleet sweep")
    args = ap.parse_args()

    print("== synthetic MSR set (published stats) ==")
    for name in wl.TRACE_NAMES:
        show(name, wl.build_trace(name, N_LOGICAL,
                                  capacity_pages=CAPACITY))

    print("\n== parametric scenario generators ==")
    for name in wl.SCENARIO_NAMES:
        show(name, wl.build_trace(name, N_LOGICAL,
                                  capacity_pages=CAPACITY))

    print("\n== real trace file (parsers.load_trace) ==")
    tr = wl.load_trace(FIXTURE, total_logical_pages=N_LOGICAL)
    show(os.path.basename(FIXTURE), tr)
    twin = wl.synthesize_like(tr, N_LOGICAL, CAPACITY)
    show("  synthetic twin", twin)

    print("\n== IR transforms compose ==")
    hot = wl.build_trace("zipf_hot", N_LOGICAL, capacity_pages=CAPACITY)
    show("zipf_hot @2x rate", hot.scale_rate(2.0))
    show("zipf_hot 30% writes", hot.shift_write_ratio(0.3))
    show("mix(hot, fixture)", wl.mix_traces([hot, tr], N_LOGICAL))

    if args.simulate:
        print("\n== one fleet sweep, three workload kinds ==")
        from repro.configs.ssd_paper import PAPER_SSD
        from repro.sweep.grid import SweepPoint
        from repro.sweep.runner import run_sweep
        cfg = PAPER_SSD.scaled(128)
        points = [SweepPoint(t, "daily", p)
                  for t in ("hm_0", "gc_pressure", FIXTURE)
                  for p in ("baseline", "ips_agc")]
        res = run_sweep(cfg, points, max_ops=8192,
                        progress=lambda s: print(f"  {s}"))
        for pt in points:
            r = res[pt]
            print(f"  {pt.key:<44} lat={r['mean_write_latency_ms']:.3f}ms "
                  f"wa={r['wa_paper']:.2f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
