"""Reproduce the paper's headline results (Figs. 9-12) on the scaled drive.

Prints the full normalized table: write latency and write amplification of
IPS / IPS-agc / cooperative vs the Turbo-Write baseline, bursty and daily.
All cells run on the batched fleet path (driver.eval_matrix -> one compiled
vmapped scan per policy/mode group).

Run: PYTHONPATH=src python examples/ssd_repro.py [--workloads hm_0,stg_0]
"""
import argparse

import numpy as np

from repro.configs.ssd_paper import PAPER_SSD
from repro.core.ssd.driver import DEFAULT_SCALE, eval_matrix
from repro.core.ssd.workloads import TRACE_NAMES


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--workloads", default=",".join(TRACE_NAMES))
    ap.add_argument("--scale", type=int, default=DEFAULT_SCALE)
    args = ap.parse_args()
    names = args.workloads.split(",")
    cfg = PAPER_SSD.scaled(args.scale)
    print(f"simulated SSD: {cfg.capacity_gb:.1f} GB (1/{args.scale} of the "
          f"paper's 384 GB), SLC cache {cfg.slc_cap_pages*cfg.num_planes} "
          f"pages")

    results = eval_matrix(
        cfg, policies=("baseline", "ips", "ips_agc", "coop"), names=names)

    agg = {}
    for mode in ("bursty", "daily"):
        print(f"\n=== {mode} (normalized to baseline) ===")
        print(f"{'workload':<9}" + "".join(
            f"{p+' lat':>12}{p+' wa':>10}" for p in ("ips", "agc", "coop")))
        for name in names:
            base = results[f"{name}/{mode}/baseline"]
            row = f"{name:<9}"
            for policy in ("ips", "ips_agc", "coop"):
                r = results[f"{name}/{mode}/{policy}"]
                nl = (r["mean_write_latency_ms"]
                      / base["mean_write_latency_ms"])
                nw = r["wa_paper"] / base["wa_paper"]
                agg.setdefault((mode, policy), []).append((nl, nw))
                row += f"{nl:>12.2f}{nw:>10.2f}"
            print(row)
    print("\n=== means (paper targets in brackets) ===")
    paper = {("bursty", "ips"): "0.77/1.0", ("daily", "ips"): "1.3/0.53",
             ("daily", "ips_agc"): "0.75/0.59",
             ("daily", "coop"): "0.78/0.67"}
    for (mode, policy), vals in agg.items():
        lat = np.mean([v[0] for v in vals])
        wa = np.mean([v[1] for v in vals])
        tgt = paper.get((mode, policy), "-")
        print(f"{mode:>7} {policy:<8} lat={lat:.2f} wa={wa:.2f}   [{tgt}]")


if __name__ == "__main__":
    main()
