"""End-to-end serving driver (the paper's kind of experiment, TPU-adapted):
batched requests decoded under all four cache-reclamation policies,
reporting the serving analogues of the paper's metrics.

The hot window is deliberately small relative to the decode length so the
policies differentiate: BASELINE migrates in bursts (stalls + 2x traffic),
IPS switches in place on fill (stalls, 1x), IPS_AGC densifies in the
background (no stalls), COOP runs an enlarged window.

Run: PYTHONPATH=src python examples/serve_ips.py [--decode 96]
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.core.tiercache.policy import Policy
from repro.models.model_zoo import build_model, make_train_batch
from repro.serve.engine import decode_loop, make_tier_spec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--decode", type=int, default=72)
    args = ap.parse_args()

    cfg = get_arch(args.arch).reduced()
    bundle = build_model(cfg)
    params = jax.jit(bundle.init)(jax.random.PRNGKey(0))
    batch = make_train_batch(cfg, args.batch, args.prompt_len)

    logical_per_tok = (cfg.num_layers * 2 * cfg.num_kv_heads * cfg.head_dim
                       * 2 * args.batch)
    print(f"{cfg.name}: batch={args.batch} prompt={args.prompt_len} "
          f"decode={args.decode}")
    print(f"{'policy':<10}{'WA':>7}{'stalls':>8}{'repacked':>10}"
          f"{'hbm MiB':>9}")
    for policy in (Policy.BASELINE, Policy.IPS, Policy.IPS_AGC, Policy.COOP):
        spec = make_tier_spec(bundle, args.prompt_len + args.decode, policy,
                              hot_window=16, page_tokens=8, group=16)
        cache, logits = jax.jit(
            lambda p, b: bundle.prefill(p, b, spec))(params, batch)
        first = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        tokens, cache, m = jax.jit(
            lambda p, c, t: decode_loop(bundle, p, c, t, args.decode, spec,
                                        policy))(params, cache, first)
        jax.block_until_ready(tokens)
        wa = float(m["hbm_write_bytes"]) / max(
            float(m["appended_tokens"]) * logical_per_tok, 1.0)
        print(f"{policy.name:<10}{wa:>7.2f}"
              f"{float(m['stall_events']):>8.0f}"
              f"{float(m['repack_tokens']):>10.0f}"
              f"{float(m['hbm_write_bytes'])/2**20:>9.2f}")


if __name__ == "__main__":
    main()
