"""Fleet sweep example: batched multi-trace / multi-seed simulation.

Runs a small parameter sweep — 3 traces x 2 seeds x {baseline, ips_agc} x
both modes, plus a cache-size sensitivity row — as a handful of compiled
batched scans, then prints baseline-normalized results and writes a
BENCH_example_sweep.json artifact.

Run: PYTHONPATH=src python examples/sweep_fleet.py [--devices N]

For the full paper figure set use the CLI:
    PYTHONPATH=src python -m repro.sweep.cli --grid paper
"""
import argparse
import os


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=os.cpu_count() or 1,
                    help="host devices to shard fleet cells across")
    ap.add_argument("--max-ops", type=int, default=None)
    args = ap.parse_args()
    if args.devices > 1 and "xla_force_host_platform_device_count" not in \
            os.environ.get("XLA_FLAGS", ""):
        os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                                   f" --xla_force_host_platform_device_count"
                                   f"={args.devices}").strip()

    from repro.configs.ssd_paper import PAPER_SSD
    from repro.sweep import SweepPoint, expand_grid, save_bench
    from repro.sweep.report import normalize_points, policy_geomeans
    from repro.sweep.runner import run_sweep

    cfg = PAPER_SSD.scaled(128)
    points = expand_grid(traces=("hm_0", "stg_0", "prxy_0"),
                         policies=("baseline", "ips_agc"),
                         seeds=(0, 1))
    # cache-size sensitivity: same cells at half / double SLC cache —
    # traced CellParams, so no extra compilation
    points += expand_grid(traces=("hm_0",), modes=("daily",),
                          policies=("baseline", "ips_agc"),
                          cache_fracs=(0.5, 2.0))

    print(f"{len(points)} cells ...")
    results = run_sweep(cfg, points, max_ops=args.max_ops,
                        progress=lambda s: print(f"  {s}"))

    lat = normalize_points(results, "mean_write_latency_ms")
    wa = normalize_points(results, "wa_paper")
    print(f"\n{'cell':<42}{'lat/base':>9}{'wa/base':>9}")
    for pt in sorted(lat, key=lambda p: p.key):
        print(f"{pt.key:<42}{lat[pt]:>9.3f}{wa[pt]:>9.3f}")
    print("\ngeomeans (unqualified cells):")
    for (mode, policy), v in sorted(policy_geomeans(results).items()):
        print(f"  {mode:>7} {policy:<8} lat={v['mean_write_latency_ms']:.3f}"
              f" wa={v['wa_paper']:.3f}")

    path = save_bench("example_sweep", {"results": results}, cfg=cfg)
    print(f"\nwrote {path}")


if __name__ == "__main__":
    main()
