"""End-to-end training example: train a ~100M-parameter LM for a few
hundred steps with checkpoint/restart.

The default below is sized for this CPU container (a ~10M model, 200
steps, minutes). For the full ~100M run on real hardware:

  PYTHONPATH=src python examples/train_lm.py --d-model 768 --layers 12 \
      --vocab 32000 --steps 300 --batch 32 --seq 512

This is the same driver as `repro.launch.train` — pjit sharding, async
checkpoints, stateless-resumable data pipeline.
"""
import sys

from repro.launch.train import main

if __name__ == "__main__":
    defaults = ["--arch", "yi-6b", "--reduced", "--d-model", "256",
                "--layers", "4", "--vocab", "2048", "--steps", "200",
                "--batch", "8", "--seq", "256", "--log-every", "20",
                "--ckpt-dir", "/tmp/repro_train_lm", "--ckpt-every", "100"]
    # user args override defaults
    sys.argv = [sys.argv[0]] + defaults + sys.argv[1:]
    main()
