"""Quickstart: the three layers of this framework in ~60 lines.

1. The paper, faithfully: simulate a hybrid 3D SSD under the baseline
   Turbo-Write cache vs In-place Switch (IPS).
2. The paper's idea on TPU: a decode step over the IPS tiered KV cache.
3. The substrate: one training step of an assigned architecture.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

# --- 1. faithful SSD simulation -----------------------------------------
from repro.configs.ssd_paper import PAPER_SSD
from repro.core.ssd.driver import eval_cell

ssd = PAPER_SSD.scaled(128)            # proportionally scaled drive
base = eval_cell(ssd, "hm_0", "baseline", "bursty")
ips = eval_cell(ssd, "hm_0", "ips", "bursty")
print(f"[ssd] bursty hm_0: baseline {base['mean_write_latency_ms']:.2f} ms"
      f" -> IPS {ips['mean_write_latency_ms']:.2f} ms "
      f"({ips['mean_write_latency_ms']/base['mean_write_latency_ms']:.2f}x,"
      f" paper: 0.77x)")

base_d = eval_cell(ssd, "hm_0", "baseline", "daily")
ips_d = eval_cell(ssd, "hm_0", "ips", "daily")
print(f"[ssd] daily hm_0 WA: baseline {base_d['wa_paper']:.2f} -> IPS "
      f"{ips_d['wa_paper']:.2f} ({ips_d['wa_paper']/base_d['wa_paper']:.2f}x,"
      f" paper: 0.53x)")

# --- 2. the idea on TPU: tiered KV cache decode --------------------------
from repro.configs import get_arch
from repro.core.tiercache.policy import Policy
from repro.models.model_zoo import build_model, make_train_batch
from repro.serve.engine import decode_loop, make_tier_spec

cfg = get_arch("gemma-2b").reduced()
bundle = build_model(cfg)
params = jax.jit(bundle.init)(jax.random.PRNGKey(0))
spec = make_tier_spec(bundle, 128, Policy.IPS_AGC, hot_window=32,
                      page_tokens=8, group=16)
cache, logits = jax.jit(lambda p, b: bundle.prefill(p, b, spec))(
    params, make_train_batch(cfg, 2, 48))
first = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
tokens, cache, metrics = decode_loop(bundle, params, cache, first, 16,
                                     spec, Policy.IPS_AGC)
print(f"[kv] decoded 16 tokens; background-repacked "
      f"{float(metrics['repack_tokens']):.0f} tokens in place, "
      f"stalls={float(metrics['stall_events']):.0f}")

# --- 3. substrate: one training step --------------------------------------
from repro.train.train_step import make_train_state, make_train_step

state = make_train_state(bundle, jax.random.PRNGKey(1))
step = jax.jit(make_train_step(bundle))
state, m = step(state, make_train_batch(cfg, 2, 64))
print(f"[train] {cfg.name} loss={float(m['loss']):.3f} "
      f"gnorm={float(m['grad_norm']):.2f}")
